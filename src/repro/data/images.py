"""Synthetic class-conditional images for the offline environment.

Mirrors the LM proxy-corpus methodology (``data.corpus.synthetic_corpus``):
the container has no ImageNet, so the ViT benchmarks train and evaluate on a
deterministic generated dataset whose *structure* a small ViT must learn —
and whose decision margins quantization error can destroy.  Absolute top-1
numbers differ from the paper by construction; the tables assert the
ordering/closeness of methods, which transfers.

Each class owns a smooth multi-sinusoid template with a class-specific
channel mix.  A sample is its class template under a random cyclic shift and
contrast, plus dense Gaussian noise and *sparse high-magnitude outlier
pixels*.  The outliers matter: they inflate static (calibration-time)
activation ranges the way real ViT outlier tokens do, which is exactly the
failure mode that separates static-MSE from per-group dynamic ABFP scaling
in the paper's vision tables.
"""

from __future__ import annotations

import numpy as np


def synthetic_images(
    n: int,
    image_size: int = 32,
    n_channels: int = 3,
    n_classes: int = 10,
    seed: int = 0,
    noise: float = 1.8,
    outlier_frac: float = 0.002,
    outlier_scale: float = 20.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic (images (N,H,W,C) f32, labels (N,) i32) dataset.

    The default ``noise`` is tuned so a 60-step reduced-ViT proxy lands
    around 95-98% top-1 — high enough to train fast, low enough that 4-bit
    quantization error shows up as measurable accuracy movement instead of
    saturating at 100%.
    """
    rng = np.random.RandomState(seed)
    H = W = image_size
    ys, xs = np.meshgrid(
        np.arange(H, dtype=np.float64) / H,
        np.arange(W, dtype=np.float64) / W,
        indexing="ij",
    )
    templates = np.zeros((n_classes, H, W, n_channels))
    for c in range(n_classes):
        for _ in range(3):  # 3 sinusoid components per class
            fy, fx = rng.uniform(0.5, 3.0, size=2)
            phase = rng.uniform(0, 2 * np.pi)
            pattern = np.sin(2 * np.pi * (fy * ys + fx * xs) + phase)
            templates[c] += pattern[..., None] * rng.randn(n_channels)
        templates[c] /= max(templates[c].std(), 1e-6)

    # balanced labels in shuffled order (deterministic)
    labels = rng.permutation(np.arange(n) % n_classes).astype(np.int32)
    images = np.empty((n, H, W, n_channels), np.float32)
    for i in range(n):
        t = templates[labels[i]]
        t = np.roll(t, (rng.randint(H), rng.randint(W)), axis=(0, 1))
        contrast = 0.7 + 0.6 * rng.rand()
        img = contrast * t + noise * rng.randn(H, W, n_channels)
        k = max(int(outlier_frac * img.size), 1)
        flat = img.reshape(-1)
        idx = rng.randint(0, flat.size, size=k)
        flat[idx] += outlier_scale * rng.randn(k)
        images[i] = img.astype(np.float32)
    return images, labels


class ImageLoader:
    """Deterministic shuffled classification batches (pure function of step).

    Same resume contract as ``data.loader.LMLoader``: any step's batch is a
    pure function of (seed, step), so checkpointing the pipeline is
    checkpointing one integer.
    """

    def __init__(self, images: np.ndarray, labels: np.ndarray,
                 global_batch: int, seed: int = 0):
        assert len(images) == len(labels) and len(images) >= global_batch
        self.images = images
        self.labels = labels
        self.global_batch = global_batch
        self.seed = seed
        self.steps_per_epoch = max(len(images) // global_batch, 1)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        epoch = step // self.steps_per_epoch
        within = step % self.steps_per_epoch
        perm = np.random.RandomState(self.seed + epoch).permutation(
            len(self.images)
        )
        rows = perm[within * self.global_batch:
                    (within + 1) * self.global_batch]
        return {"images": self.images[rows], "labels": self.labels[rows]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def eval_image_batches(images: np.ndarray, labels: np.ndarray, batch: int,
                       max_batches: int | None = None):
    """Sequential non-shuffled eval batches."""
    n_batches = len(images) // batch
    if max_batches is not None:
        n_batches = min(n_batches, max_batches)
    for b in range(n_batches):
        sl = slice(b * batch, (b + 1) * batch)
        yield {"images": images[sl], "labels": labels[sl]}

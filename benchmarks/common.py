"""Benchmark substrate: trained proxy models + PPL evaluation.

Methodology (EXPERIMENTS.md §Method): the paper evaluates PTQ on
wikitext2-finetuned OPT checkpoints; this container has no checkpoints or
datasets, so every table is reproduced on *proxy* OPT-family models trained
in-framework on the deterministic synthetic corpus.  Absolute PPLs differ
from the paper by construction; every table's CLAIM is the *ordering /
closeness* of methods, which transfers (and is what we assert).

All trained models and calibrations are cached under artifacts/bench/ so
re-runs only pay for evaluation.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_config
from repro.core.policy import QuantPolicy, preset
from repro.data.corpus import synthetic_corpus
from repro.data.loader import LMLoader, eval_batches
from repro.models import build_model
from repro.models import quant_transforms as qt
from repro.nn.module import unbox
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine
from repro.train.step import TrainStepConfig, make_train_step

ART = os.environ.get("BENCH_ART", "artifacts/bench")
VOCAB = 503
SEQ = 128


# ---------------------------------------------------------------- corpus
_corpus_cache = {}


def corpus(n_tokens: int = 400_000, seed: int = 0) -> np.ndarray:
    key = (n_tokens, seed)
    if key not in _corpus_cache:
        path = os.path.join(ART, f"corpus_{n_tokens}_{seed}.npy")
        if os.path.exists(path):
            _corpus_cache[key] = np.load(path)
        else:
            arr = synthetic_corpus(n_tokens, vocab=VOCAB, seed=seed)
            os.makedirs(ART, exist_ok=True)
            np.save(path, arr)
            _corpus_cache[key] = arr
    return _corpus_cache[key]


def split(stream):
    n_eval = max(len(stream) // 10, SEQ * 16 + 1)
    return stream[:-n_eval], stream[-n_eval:]


def adapt_batch(cfg, batch, step: int = 0):
    """Add stub modality-frontend tensors for vlm/encdec proxies.

    The frontends are STUBS per the assignment (input_specs provide
    precomputed embeddings); benchmarks feed deterministic pseudo-random
    embeddings so PPL comparisons between policies stay apples-to-apples.
    """
    fam = getattr(cfg, "family", "dense")
    if fam not in ("vlm", "encdec"):
        return batch
    B = batch["tokens"].shape[0]
    rng = np.random.RandomState(10_000 + step)
    out = dict(batch)
    if fam == "vlm":
        out["patch_embeds"] = rng.randn(
            B, cfg.vision_patches, cfg.d_model).astype(np.float32) * 0.02
        # loss slices the patch positions off; labels align with tokens
    if fam == "encdec":
        S = batch["tokens"].shape[1]
        out["frames"] = rng.randn(B, S, cfg.d_model).astype(np.float32) * 0.02
    return out


class AdaptedLoader:
    """batch_at() wrapper adding modality stubs (keeps resume purity)."""

    def __init__(self, cfg, loader):
        self.cfg = cfg
        self.loader = loader
        self.tokens_per_step = getattr(loader, "tokens_per_step", None)

    def batch_at(self, step: int):
        return adapt_batch(self.cfg, self.loader.batch_at(step), step)


# ----------------------------------------------------------- proxy models
def proxy_config(name: str):
    """OPT-family proxies + reduced assigned archs for Table X."""
    if name.startswith("opt-"):
        cfg = get_config("opt-tiny")
        if name == "opt-proxy-s":
            return cfg.replace(name=name, n_layers=2, d_model=96, n_heads=4,
                               n_kv=4, head_dim=24, d_ff=384, vocab=VOCAB)
        if name == "opt-proxy-m":
            return cfg.replace(name=name, n_layers=4, d_model=160, n_heads=4,
                               n_kv=4, head_dim=40, d_ff=640, vocab=VOCAB)
        if name == "opt-proxy-l":
            return cfg.replace(name=name, n_layers=6, d_model=256, n_heads=8,
                               n_kv=8, head_dim=32, d_ff=1024, vocab=VOCAB)
        raise ValueError(name)
    # reduced assigned archs (Table X "additional models")
    cfg = get_config(name).reduced().replace(vocab=VOCAB, scan_layers=False)
    return cfg.replace(name=name + "-proxy")


def train_proxy(name: str, steps: int = 500, seed: int = 0,
                batch: int = 8, force: bool = False):
    """Train (or load cached) proxy; returns (cfg, model, params, meta)."""
    cfg = proxy_config(name)
    model = build_model(cfg)
    ckdir = os.path.join(ART, "models", f"{name}_s{steps}_b{batch}_{seed}")
    params0 = unbox(model.init(jax.random.PRNGKey(seed)))
    if not force and store.list_steps(ckdir):
        step = store.list_steps(ckdir)[-1]
        params = store.restore_pytree(ckdir, step, jax.eval_shape(
            lambda: params0))
        meta = store.load_metadata(ckdir, step)
        return cfg, model, params, meta

    stream, _ = split(corpus())
    loader = LMLoader(stream, seq_len=SEQ, global_batch=batch, seed=seed)
    opt = AdamW(lr=warmup_cosine(3e-3, min(50, steps // 10), steps),
                weight_decay=0.01)
    ost = opt.init(params0)
    step_fn = jax.jit(make_train_step(model, opt, QuantPolicy(),
                                      TrainStepConfig()),
                      donate_argnums=(0, 1))
    params = params0
    loss = float("nan")
    for s in range(steps):
        params, ost, m = step_fn(params, ost,
                                 adapt_batch(cfg, loader.batch_at(s), s))
        loss = float(m["loss"])
    meta = {"final_train_loss": loss, "steps": steps}
    store.save_pytree(ckdir, steps, params, metadata=meta)
    store.mark_committed(ckdir, steps)
    return cfg, model, params, meta


def finetune_qat(model, params, policy: QuantPolicy, steps: int = 60,
                 seed: int = 1, batch: int = 8, lr: float = 3e-4):
    """QAT (paper §II-C): ABFP forward + PWL-STE backward fine-tuning."""
    stream, _ = split(corpus())
    loader = LMLoader(stream, seq_len=SEQ, global_batch=batch,
                      seed=seed + 100)
    opt = AdamW(lr=lr, weight_decay=0.0)
    ost = opt.init(params)
    pol = policy.with_ste(True) if not _has_ste(policy) else policy
    step_fn = jax.jit(make_train_step(model, opt, pol, TrainStepConfig()),
                      donate_argnums=(1,))
    for s in range(steps):
        params, ost, m = step_fn(params, ost,
                                 adapt_batch(model.cfg, loader.batch_at(s), s))
    return params


def _has_ste(policy: QuantPolicy) -> bool:
    return any(
        getattr(policy, r) is not None and getattr(policy, r).ste
        for r in ("input", "weight", "output")
    )


# ------------------------------------------------------------------- eval
def eval_ppl(model, params, policy: QuantPolicy, q=None,
             max_batches: int = 12, batch: int = 8) -> float:
    _, ev = split(corpus())
    losses = []
    loss_fn = jax.jit(
        lambda p, b: model.loss(p, b, policy, q=q)[0]
    ) if q is None else None
    for i, b in enumerate(eval_batches(ev, SEQ, batch,
                                       max_batches=max_batches)):
        b = adapt_batch(model.cfg, b, 90_000 + i)
        if loss_fn is not None:
            losses.append(float(loss_fn(params, b)))
        else:
            losses.append(float(model.loss(params, b, policy, q=q)[0]))
    return float(np.exp(np.mean(losses)))


# ------------------------------------------------------------- calibration
_calib_cache = {}


def calibrated(name, model, params, *, outer=False, n_batches: int = 4,
               batch: int = 4):
    """Calibration pass (cached in-process per model identity)."""
    key = (name, outer, id(params))
    if key not in _calib_cache:
        stream, _ = split(corpus())
        loader = LMLoader(stream, seq_len=SEQ, global_batch=batch, seed=77)
        batches = [adapt_batch(model.cfg, loader.batch_at(i), 80_000 + i)
                   for i in range(n_batches)]
        _calib_cache[key] = qt.calibrate(
            model, params, batches, preset("w4a8_mse"), collect_outer=outer
        )
    return _calib_cache[key]


# ------------------------------------------------------------------ output
class Report:
    """Collects benchmark rows + claim checks; writes JSON + CSV."""

    def __init__(self, path_prefix: str):
        self.rows = []
        self.claims = []
        self.prefix = path_prefix

    def row(self, table: str, **kw):
        rec = {"table": table, **kw}
        self.rows.append(rec)
        cells = ",".join(f"{k}={v}" for k, v in kw.items())
        print(f"[{table}] {cells}", flush=True)

    def claim(self, table: str, text: str, ok: bool, detail: str = ""):
        self.claims.append(
            {"table": table, "claim": text, "ok": bool(ok), "detail": detail}
        )
        print(f"[{table}] CLAIM {'OK ' if ok else 'FAIL'}: {text} {detail}",
              flush=True)

    def save(self):
        os.makedirs(os.path.dirname(self.prefix) or ".", exist_ok=True)
        with open(self.prefix + ".json", "w") as f:
            json.dump({"rows": self.rows, "claims": self.claims}, f, indent=2)
        with open(self.prefix + ".csv", "w") as f:
            keys = ["table"] + sorted(
                {k for r in self.rows for k in r} - {"table"}
            )
            f.write(",".join(keys) + "\n")
            for r in self.rows:
                f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")

"""Benchmark substrate: trained proxy models + PPL evaluation.

Methodology (EXPERIMENTS.md §Method): the paper evaluates PTQ on
wikitext2-finetuned OPT checkpoints; this container has no checkpoints or
datasets, so every table is reproduced on *proxy* OPT-family models trained
in-framework on the deterministic synthetic corpus.  Absolute PPLs differ
from the paper by construction; every table's CLAIM is the *ordering /
closeness* of methods, which transfers (and is what we assert).

All trained models and calibrations are cached under artifacts/bench/ so
re-runs only pay for evaluation.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_config
from repro.core.policy import Policy, QuantPolicy, policies_of, preset
from repro.data.corpus import synthetic_corpus
from repro.data.images import ImageLoader, eval_image_batches, synthetic_images
from repro.data.loader import LMLoader, eval_batches
from repro.models import build_model
from repro.models import quant_transforms as qt
from repro.nn.module import unbox
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine
from repro.train.step import TrainStepConfig, make_train_step

ART = os.environ.get("BENCH_ART", "artifacts/bench")
VOCAB = 503
SEQ = 128


# ---------------------------------------------------------------- corpus
_corpus_cache = {}


def corpus(n_tokens: int = 400_000, seed: int = 0) -> np.ndarray:
    key = (n_tokens, seed)
    if key not in _corpus_cache:
        path = os.path.join(ART, f"corpus_{n_tokens}_{seed}.npy")
        if os.path.exists(path):
            _corpus_cache[key] = np.load(path)
        else:
            arr = synthetic_corpus(n_tokens, vocab=VOCAB, seed=seed)
            os.makedirs(ART, exist_ok=True)
            np.save(path, arr)
            _corpus_cache[key] = arr
    return _corpus_cache[key]


def split(stream):
    n_eval = max(len(stream) // 10, SEQ * 16 + 1)
    return stream[:-n_eval], stream[-n_eval:]


def adapt_batch(cfg, batch, step: int = 0):
    """Add stub modality-frontend tensors for vlm/encdec proxies.

    The frontends are STUBS per the assignment (input_specs provide
    precomputed embeddings); benchmarks feed deterministic pseudo-random
    embeddings so PPL comparisons between policies stay apples-to-apples.
    """
    fam = getattr(cfg, "family", "dense")
    if fam not in ("vlm", "encdec"):
        return batch
    B = batch["tokens"].shape[0]
    rng = np.random.RandomState(10_000 + step)
    out = dict(batch)
    if fam == "vlm":
        out["patch_embeds"] = rng.randn(
            B, cfg.vision_patches, cfg.d_model).astype(np.float32) * 0.02
        # loss slices the patch positions off; labels align with tokens
    if fam == "encdec":
        S = batch["tokens"].shape[1]
        out["frames"] = rng.randn(B, S, cfg.d_model).astype(np.float32) * 0.02
    return out


class AdaptedLoader:
    """batch_at() wrapper adding modality stubs (keeps resume purity)."""

    def __init__(self, cfg, loader):
        self.cfg = cfg
        self.loader = loader
        self.tokens_per_step = getattr(loader, "tokens_per_step", None)

    def batch_at(self, step: int):
        return adapt_batch(self.cfg, self.loader.batch_at(step), step)


# ----------------------------------------------------------- proxy models
def proxy_config(name: str):
    """OPT-family proxies + reduced assigned archs for Table X."""
    if name.startswith("opt-"):
        cfg = get_config("opt-tiny")
        if name == "opt-proxy-s":
            return cfg.replace(name=name, n_layers=2, d_model=96, n_heads=4,
                               n_kv=4, head_dim=24, d_ff=384, vocab=VOCAB)
        if name == "opt-proxy-m":
            return cfg.replace(name=name, n_layers=4, d_model=160, n_heads=4,
                               n_kv=4, head_dim=40, d_ff=640, vocab=VOCAB)
        if name == "opt-proxy-l":
            return cfg.replace(name=name, n_layers=6, d_model=256, n_heads=8,
                               n_kv=8, head_dim=32, d_ff=1024, vocab=VOCAB)
        if name == "opt-proxy-d":
            # deep-thin proxy for the layer-sensitivity (mixed_table) sweep:
            # enough depth that W8A8 endcaps are a small fraction of the
            # weight-bits budget (2/12 blocks), thin dims to stay CPU-cheap
            return cfg.replace(name=name, n_layers=12, d_model=64, n_heads=4,
                               n_kv=4, head_dim=16, d_ff=256, vocab=VOCAB)
        raise ValueError(name)
    # reduced assigned archs (Table X "additional models")
    cfg = get_config(name).reduced().replace(vocab=VOCAB, scan_layers=False)
    return cfg.replace(name=name + "-proxy")


def _train_cached(name: str, cfg, model, make_loader, steps: int, seed: int,
                  batch: int, force: bool):
    """Shared proxy-training scaffold: checkpoint-restore or train+save.

    ``make_loader`` is called only on cache miss and must return an object
    with ``batch_at(step) -> batch dict`` (AdaptedLoader / ImageLoader).
    One copy of the cache-dir naming, restore, optimizer and loop contract
    serves both the LM and the ViT benchmark paths.
    """
    ckdir = os.path.join(ART, "models", f"{name}_s{steps}_b{batch}_{seed}")
    params0 = unbox(model.init(jax.random.PRNGKey(seed)))
    if not force and store.list_steps(ckdir):
        step = store.list_steps(ckdir)[-1]
        params = store.restore_pytree(ckdir, step, jax.eval_shape(
            lambda: params0))
        meta = store.load_metadata(ckdir, step)
        return cfg, model, params, meta

    loader = make_loader()
    opt = AdamW(lr=warmup_cosine(3e-3, min(50, steps // 10), steps),
                weight_decay=0.01)
    ost = opt.init(params0)
    step_fn = jax.jit(make_train_step(model, opt, QuantPolicy(),
                                      TrainStepConfig()),
                      donate_argnums=(0, 1))
    params = params0
    loss = float("nan")
    for s in range(steps):
        params, ost, m = step_fn(params, ost, loader.batch_at(s))
        loss = float(m["loss"])
    meta = {"final_train_loss": loss, "steps": steps}
    store.save_pytree(ckdir, steps, params, metadata=meta)
    store.mark_committed(ckdir, steps)
    return cfg, model, params, meta


def train_proxy(name: str, steps: int = 500, seed: int = 0,
                batch: int = 8, force: bool = False):
    """Train (or load cached) proxy; returns (cfg, model, params, meta)."""
    cfg = proxy_config(name)
    model = build_model(cfg)

    def make_loader():
        stream, _ = split(corpus())
        return AdaptedLoader(cfg, LMLoader(stream, seq_len=SEQ,
                                           global_batch=batch, seed=seed))

    return _train_cached(name, cfg, model, make_loader, steps, seed, batch,
                         force)


def finetune_qat(model, params, policy: Policy, steps: int = 60,
                 seed: int = 1, batch: int = 8, lr: float = 3e-4):
    """QAT (paper §II-C): ABFP forward + PWL-STE backward fine-tuning."""
    stream, _ = split(corpus())
    loader = LMLoader(stream, seq_len=SEQ, global_batch=batch,
                      seed=seed + 100)
    opt = AdamW(lr=lr, weight_decay=0.0)
    ost = opt.init(params)
    pol = policy.with_ste(True) if not _has_ste(policy) else policy
    step_fn = jax.jit(make_train_step(model, opt, pol, TrainStepConfig()),
                      donate_argnums=(1,))
    for s in range(steps):
        params, ost, m = step_fn(params, ost,
                                 adapt_batch(model.cfg, loader.batch_at(s), s))
    return params


def _has_ste(policy: Policy) -> bool:
    return any(
        getattr(p, r) is not None and getattr(p, r).ste
        for p in policies_of(policy) for r in ("input", "weight", "output")
    )


# ------------------------------------------------------------------- eval
def eval_ppl(model, params, policy: Policy, q=None,
             max_batches: int = 12, batch: int = 8) -> float:
    _, ev = split(corpus())
    losses = []
    loss_fn = jax.jit(
        lambda p, b: model.loss(p, b, policy, q=q)[0]
    ) if q is None else None
    for i, b in enumerate(eval_batches(ev, SEQ, batch,
                                       max_batches=max_batches)):
        b = adapt_batch(model.cfg, b, 90_000 + i)
        if loss_fn is not None:
            losses.append(float(loss_fn(params, b)))
        else:
            losses.append(float(model.loss(params, b, policy, q=q)[0]))
    return float(np.exp(np.mean(losses)))


# ------------------------------------------------------------ vision eval
# ViT proxies follow the same methodology as the OPT proxies: trained
# in-framework on a deterministic synthetic dataset; tables assert the
# ordering/closeness of methods (top-1 here, PPL for LMs), not absolutes.
N_TRAIN_IMAGES = 4096
N_EVAL_IMAGES = 1024

_image_cache = {}


def image_data(cfg, seed: int = 0, noise: float = 1.8,
               outlier_frac: float = 0.002, outlier_scale: float = 20.0):
    """(train_images, train_labels, eval_images, eval_labels), cached.

    Every generation parameter — config dims, split sizes AND the
    difficulty knobs — is part of the cache key/filename, so tuning any of
    them regenerates instead of silently serving stale arrays.
    """
    gen = (cfg.image_size, cfg.n_channels, cfg.n_classes, seed,
           noise, outlier_frac, outlier_scale)
    if gen not in _image_cache:
        path = os.path.join(
            ART,
            f"images_{cfg.image_size}x{cfg.n_channels}_{cfg.n_classes}c"
            f"_n{noise}_of{outlier_frac}_os{outlier_scale}"
            f"_{N_TRAIN_IMAGES}+{N_EVAL_IMAGES}_{seed}.npz")
        if os.path.exists(path):
            z = np.load(path)
            _image_cache[gen] = (z["xtr"], z["ytr"], z["xev"], z["yev"])
        else:
            n = N_TRAIN_IMAGES + N_EVAL_IMAGES
            x, y = synthetic_images(
                n, image_size=cfg.image_size, n_channels=cfg.n_channels,
                n_classes=cfg.n_classes, seed=seed, noise=noise,
                outlier_frac=outlier_frac, outlier_scale=outlier_scale)
            xtr, ytr = x[:N_TRAIN_IMAGES], y[:N_TRAIN_IMAGES]
            xev, yev = x[N_TRAIN_IMAGES:], y[N_TRAIN_IMAGES:]
            os.makedirs(ART, exist_ok=True)
            np.savez(path, xtr=xtr, ytr=ytr, xev=xev, yev=yev)
            _image_cache[gen] = (xtr, ytr, xev, yev)
    return _image_cache[gen]


def vit_proxy_config(name: str):
    """Reduced ViT/DeiT proxies (eager-unrolled for calibration taps)."""
    if name == "vit-proxy-s":
        return get_config("vit-b16").reduced().replace(
            name=name, scan_layers=False)
    if name == "deit-proxy-s":
        # differentiated dims so the table has two genuinely distinct models
        return get_config("deit-s16").reduced().replace(
            name=name, n_layers=3, d_model=96, n_heads=6, n_kv=6,
            head_dim=16, d_ff=192, scan_layers=False)
    raise ValueError(name)


def train_vit_proxy(name: str, steps: int = 500, seed: int = 0,
                    batch: int = 32, force: bool = False):
    """Train (or load cached) ViT proxy; returns (cfg, model, params, meta)."""
    cfg = vit_proxy_config(name)
    model = build_model(cfg)

    def make_loader():
        xtr, ytr, _, _ = image_data(cfg)
        return ImageLoader(xtr, ytr, global_batch=batch, seed=seed)

    return _train_cached(name, cfg, model, make_loader, steps, seed, batch,
                         force)


def eval_top1(model, params, policy: Policy, q=None,
              max_batches: int = 16, batch: int = 64) -> float:
    """Held-out top-1 accuracy under ``policy`` (+ optional static q tree)."""
    _, _, xev, yev = image_data(model.cfg)
    correct = total = 0
    logits_fn = jax.jit(
        lambda p, b: model.apply(p, b, policy)[0]
    ) if q is None else None
    for b in eval_image_batches(xev, yev, batch, max_batches=max_batches):
        if logits_fn is not None:
            logits = logits_fn(params, b)
        else:
            logits = model.apply(params, b, policy, q=q)[0]
        pred = np.asarray(jnp.argmax(logits, axis=-1))
        correct += int((pred == b["labels"]).sum())
        total += len(b["labels"])
    return correct / max(total, 1)


_vit_calib_cache = {}


def vit_calib_batches(model, *, n_batches: int = 4, batch: int = 16):
    """Deterministic image calibration batches (ViT recipe/calib input)."""
    xtr, ytr, _, _ = image_data(model.cfg)
    loader = ImageLoader(xtr, ytr, global_batch=batch, seed=77)
    return [loader.batch_at(i) for i in range(n_batches)]


def calibrated_vit(name, model, params, *, n_batches: int = 4,
                   batch: int = 16):
    """Calibration pass over training images (cached per model identity)."""
    key = (name, id(params))
    if key not in _vit_calib_cache:
        batches = vit_calib_batches(model, n_batches=n_batches, batch=batch)
        _vit_calib_cache[key] = qt.calibrate(
            model, params, batches, preset("w4a8_mse")
        )
    return _vit_calib_cache[key]


# ------------------------------------------------------------- calibration
_calib_cache = {}


def calib_batches(model, *, n_batches: int = 4, batch: int = 4):
    """The deterministic calibration batches every benchmark shares."""
    stream, _ = split(corpus())
    loader = LMLoader(stream, seq_len=SEQ, global_batch=batch, seed=77)
    return [adapt_batch(model.cfg, loader.batch_at(i), 80_000 + i)
            for i in range(n_batches)]


def calibrated(name, model, params, *, outer=False, n_batches: int = 4,
               batch: int = 4):
    """Calibration pass (cached in-process per model identity)."""
    key = (name, outer, id(params))
    if key not in _calib_cache:
        batches = calib_batches(model, n_batches=n_batches, batch=batch)
        _calib_cache[key] = qt.calibrate(
            model, params, batches, preset("w4a8_mse"), collect_outer=outer
        )
    return _calib_cache[key]


# ---------------------------------------------------------------- recipes
_recipe_cache = {}


def run_recipe(name, model, params, recipe, policy=None, *, calib=None,
               batches=None):
    """Apply a QuantRecipe to a proxy (cached per model identity).

    Observation passes use the benchmarks' calibration convention
    (``preset('w4a8_mse')``, same as ``calibrated``) so recipe-applied
    results are directly comparable with the legacy driver rows.  A cached
    ``calib`` from ``calibrated()`` short-circuits the first collection;
    the engine re-collects automatically once a pass mutates params.
    """
    from repro.core import recipe as rc

    rec = rc.as_recipe(recipe)
    pol_key = getattr(policy, "name", None) or rec.policy_preset
    key = (name, rec.name, pol_key, id(params))
    if key not in _recipe_cache:
        _recipe_cache[key] = rc.apply_recipe(
            rec, model, params,
            batches if batches is not None else calib_batches(model),
            policy, calib=calib, calib_policy=preset("w4a8_mse"),
        )
    return _recipe_cache[key]


# ------------------------------------------------------------------ output
class Report:
    """Collects benchmark rows + claim checks; writes JSON + CSV."""

    def __init__(self, path_prefix: str):
        self.rows = []
        self.claims = []
        self.prefix = path_prefix

    def row(self, table: str, **kw):
        rec = {"table": table, **kw}
        self.rows.append(rec)
        cells = ",".join(f"{k}={v}" for k, v in kw.items())
        print(f"[{table}] {cells}", flush=True)

    def claim(self, table: str, text: str, ok: bool, detail: str = ""):
        self.claims.append(
            {"table": table, "claim": text, "ok": bool(ok), "detail": detail}
        )
        print(f"[{table}] CLAIM {'OK ' if ok else 'FAIL'}: {text} {detail}",
              flush=True)

    def save(self):
        os.makedirs(os.path.dirname(self.prefix) or ".", exist_ok=True)
        with open(self.prefix + ".json", "w") as f:
            json.dump({"rows": self.rows, "claims": self.claims}, f, indent=2)
        with open(self.prefix + ".csv", "w") as f:
            keys = ["table"] + sorted(
                {k for r in self.rows for k in r} - {"table"}
            )
            f.write(",".join(keys) + "\n")
            for r in self.rows:
                f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")

"""Benchmark harness entry point: ``python -m benchmarks.run``.

Runs one benchmark per paper table/figure (see benchmarks/tables.py) on
in-framework-trained proxy models, printing rows + qualitative claim
checks, and writes artifacts/bench/results.{json,csv}.

Also emits the roofline summary (reads the dry-run artifacts produced by
``python -m repro.launch.dryrun --all``) so the two reports land in one
place for EXPERIMENTS.md.

Flags:
    --only table1,fig3     run a subset
    --quick                tiny proxies / few steps (CI smoke, ~2 min)
    --steps N --qat-steps N  override training budgets
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time


def roofline_summary(out_dir="artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*__sp.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        t = rec["terms"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "dominant": t["dominant"],
            "bound_s": round(t["roofline_bound_s"], 4),
            "compute_frac": round(t["compute_fraction_of_bound"], 4),
            "hbm_gb": rec["hbm_gb_per_device"],
            "useful_ratio": round(rec["useful_compute_ratio"], 3),
        })
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated table names")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--qat-steps", type=int, default=None)
    ap.add_argument("--out", default="artifacts/bench/results")
    args = ap.parse_args()

    from benchmarks import common as C
    from benchmarks import tables as T

    steps = args.steps or (60 if args.quick else 500)
    qat_steps = args.qat_steps or (10 if args.quick else 60)

    rep = C.Report(args.out)
    names = list(T.ALL) if not args.only else args.only.split(",")
    t0 = time.time()
    for name in names:
        fn = T.ALL[name]
        print(f"=== {name} ===", flush=True)
        kw = {"steps": steps}
        if "qat_steps" in fn.__code__.co_varnames[: fn.__code__.co_argcount]:
            kw["qat_steps"] = qat_steps
        fn(rep, **kw)
    # roofline summary (from dry-run artifacts, if present)
    for r in roofline_summary():
        rep.row("roofline", **r)
    rep.save()
    n_ok = sum(c["ok"] for c in rep.claims)
    print(f"\n{len(rep.rows)} rows, claims {n_ok}/{len(rep.claims)} OK, "
          f"{time.time() - t0:.0f}s -> {args.out}.json", flush=True)
    return 0 if n_ok == len(rep.claims) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""One benchmark per paper table/figure (Tables I-VIII, X; Figs 3-5).

Each function evaluates trained proxy models under the paper's exact
configuration grid and asserts the table's QUALITATIVE claim (ordering /
closeness of methods).  See benchmarks/common.py for the proxy methodology.

All PTQ transforms run through the QuantRecipe pipeline (``C.run_recipe``);
``methods_table`` is the method-combination survey the recipe engine
exists for (single methods vs composites, plus a bit-exactness check
against the legacy manual driver chain).
"""

from __future__ import annotations

import warnings

import jax
import numpy as np

from benchmarks import common as C
from repro.core.formats import INT4
from repro.core.policy import preset
from repro.models import quant_transforms as qt

MODELS = ["opt-proxy-s", "opt-proxy-m"]


def _trees_equal(a, b) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    return ta == tb and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb)
    )


def _fp32_ppl(name, model, params, cache={}):
    if name not in cache:
        cache[name] = C.eval_ppl(model, params, preset("fp32"))
    return cache[name]


# ---------------------------------------------------------------- Table I
def table1(rep: C.Report, steps: int):
    """W4A4: static MSE calibration vs ABFP (n=64)."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        fp = _fp32_ppl(name, model, params)
        calib = C.calibrated(name, model, params)
        q = C.run_recipe(name, model, params, "static_mse",
                         preset("w4a4_mse"), calib=calib).qtree
        mse = C.eval_ppl(model, params, preset("w4a4_mse"), q=q)
        abfp = C.eval_ppl(model, params, preset("w4a4_abfp"))
        rep.row("table1", model=name, fp32=fp, mse=round(mse, 3),
                abfp=round(abfp, 3))
        # Proxy-scale note (EXPERIMENTS.md §Benchmarks): the paper's PPL
        # *cliff* (1130 vs 33) needs the extreme activation outliers of
        # large-scale-pretrained OPT; 700-step proxies develop the correct
        # ORDERING (MSE strictly worse than ABFP, ABFP near fp32) but not
        # the cliff.  The ordering is the transferable claim.
        rep.claim("table1",
                  f"{name}: W4A4 static-MSE strictly worse than ABFP; "
                  "ABFP stays near fp32",
                  mse > 1.05 * abfp and abfp < 1.3 * fp,
                  f"mse={mse:.2f} abfp={abfp:.2f} fp={fp:.2f}")


# --------------------------------------------------------------- Table II
def table2(rep: C.Report, steps: int):
    """4-bit integer vs FP4 (E2M1 / E1M2) weights+activations, ABFP n=64."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        fp = _fp32_ppl(name, model, params)
        w4a4 = C.eval_ppl(model, params, preset("w4a4_abfp"))
        e2m1 = C.eval_ppl(model, params, preset("w4a4_e2m1"))
        e1m2 = C.eval_ppl(model, params, preset("w4a4_e1m2"))
        rep.row("table2", model=name, fp32=fp, w4a4=round(w4a4, 3),
                e2m1=round(e2m1, 3), e1m2=round(e1m2, 3))
        rep.claim("table2",
                  f"{name}: E1M2 ~ INT4 under ABFP (near-uniform grid)",
                  abs(e1m2 - w4a4) / w4a4 < 0.25,
                  f"int4={w4a4:.2f} e1m2={e1m2:.2f} e2m1={e2m1:.2f}")


# -------------------------------------------------------------- Table III
def table3(rep: C.Report, steps: int, qat_steps: int):
    """W4A4 accuracy recovery: ABFP vs ABFP-QAT vs ABFP-SQ."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        fp = _fp32_ppl(name, model, params)
        pol = preset("w4a4_abfp")
        abfp = C.eval_ppl(model, params, pol)
        qp = C.finetune_qat(model, params, pol, steps=qat_steps)
        qat = C.eval_ppl(model, qp, pol)
        calib = C.calibrated(name, model, params)
        sq_params = C.run_recipe(name, model, params, "smoothquant",
                                 preset("w4a8_mse"), calib=calib).params
        sq = C.eval_ppl(model, sq_params, pol)
        rep.row("table3", model=name, fp32=fp, abfp=round(abfp, 3),
                abfp_qat=round(qat, 3), abfp_sq=round(sq, 3))
        rep.claim("table3",
                  f"{name}: QAT and SQ both improve over vanilla ABFP",
                  qat < abfp and sq < abfp * 1.02,
                  f"abfp={abfp:.2f} qat={qat:.2f} sq={sq:.2f}")


# --------------------------------------------------------------- Table IV
def table4(rep: C.Report, steps: int):
    """W4A8: static MSE vs ABFP — MSE usable here, ABFP still better."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        fp = _fp32_ppl(name, model, params)
        calib = C.calibrated(name, model, params)
        q = C.run_recipe(name, model, params, "static_mse",
                         preset("w4a8_mse"), calib=calib).qtree
        mse = C.eval_ppl(model, params, preset("w4a8_mse"), q=q)
        abfp = C.eval_ppl(model, params, preset("w4a8_abfp"))
        rep.row("table4", model=name, fp32=fp, mse=round(mse, 3),
                abfp=round(abfp, 3))
        rep.claim("table4",
                  f"{name}: at W4A8 MSE is usable; ABFP near-baseline",
                  mse < 20 * fp and abfp < mse and abfp < 1.6 * fp,
                  f"mse={mse:.2f} abfp={abfp:.2f} fp={fp:.2f}")


# ---------------------------------------------------------------- Table V
def table5(rep: C.Report, steps: int):
    """INT4 weights + E4M3 acts (ABFP / ABFP-SQ) vs GPTQ W4A16."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        fp = _fp32_ppl(name, model, params)
        abfp = C.eval_ppl(model, params, preset("w4_ae4m3_abfp"))
        calib = C.calibrated(name, model, params, outer=True)
        sq_params = C.run_recipe(name, model, params, "smoothquant",
                                 preset("w4a8_mse"), calib=calib).params
        sq = C.eval_ppl(model, sq_params, preset("w4_ae4m3_abfp"))
        gq_params = C.run_recipe(name, model, params, "gptq",
                                 preset("w4a8_mse"), calib=calib).params
        gptq = C.eval_ppl(model, gq_params, preset("fp32"))  # W4A16
        rep.row("table5", model=name, fp32=fp, abfp=round(abfp, 3),
                abfp_sq=round(sq, 3), gptq_w4a16=round(gptq, 3))
        rep.claim("table5",
                  f"{name}: W4-AE4M3 ABFP(-SQ) competitive with GPTQ W4A16",
                  min(abfp, sq) < gptq * 1.15,
                  f"abfp={abfp:.2f} sq={sq:.2f} gptq={gptq:.2f}")


# --------------------------------------------------------------- Table VI
def table6(rep: C.Report, steps: int):
    """E4M3 vs INT8 activations: no significant difference under ABFP."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        e4m3 = C.eval_ppl(model, params, preset("w4_ae4m3_abfp"))
        int8 = C.eval_ppl(model, params, preset("w4a8_abfp"))
        calib = C.calibrated(name, model, params)
        sq_params = C.run_recipe(name, model, params, "smoothquant",
                                 preset("w4a8_mse"), calib=calib).params
        e4m3_sq = C.eval_ppl(model, sq_params, preset("w4_ae4m3_abfp"))
        int8_sq = C.eval_ppl(model, sq_params, preset("w4a8_abfp"))
        rep.row("table6", model=name, e4m3=round(e4m3, 3),
                int8=round(int8, 3), e4m3_sq=round(e4m3_sq, 3),
                int8_sq=round(int8_sq, 3))
        rep.claim("table6",
                  f"{name}: E4M3 ~ INT8 activations (no significant gain)",
                  abs(e4m3 - int8) / int8 < 0.10,
                  f"e4m3={e4m3:.2f} int8={int8:.2f}")


# -------------------------------------------------------------- Table VII
def table7(rep: C.Report, steps: int, qat_steps: int):
    """W4A8 recovery: ABFP / ABFP-QAT / ABFP-SQ (vs GPTQ W4A16 column)."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        fp = _fp32_ppl(name, model, params)
        pol = preset("w4a8_abfp")
        abfp = C.eval_ppl(model, params, pol)
        qp = C.finetune_qat(model, params, pol, steps=qat_steps)
        qat = C.eval_ppl(model, qp, pol)
        calib = C.calibrated(name, model, params, outer=True)
        sq = C.eval_ppl(
            model,
            C.run_recipe(name, model, params, "smoothquant",
                         preset("w4a8_mse"), calib=calib).params,
            pol)
        gq_params = C.run_recipe(name, model, params, "gptq",
                                 preset("w4a8_mse"), calib=calib).params
        gptq = C.eval_ppl(model, gq_params, preset("fp32"))
        rep.row("table7", model=name, fp32=fp, abfp=round(abfp, 3),
                abfp_qat=round(qat, 3), abfp_sq=round(sq, 3),
                gptq_w4a16=round(gptq, 3))
        rep.claim("table7",
                  f"{name}: QAT/SQ recover W4A8 toward baseline",
                  qat <= abfp and sq <= abfp * 1.02 and qat < 1.35 * fp,
                  f"abfp={abfp:.2f} qat={qat:.2f} sq={sq:.2f} fp={fp:.2f}")


# ------------------------------------------------------------- Table VIII
def table8(rep: C.Report, steps: int):
    """RPTQ (channel-cluster static scales) vs ABFP, W4A4 and W4A8."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        calib = C.calibrated(name, model, params)
        q_rptq = C.run_recipe(name, model, params, "rptq",
                              preset("w4a8_mse"), calib=calib).qtree
        rows = {}
        for fmt_name, pol_rptq, pol_abfp in (
            ("w4a4", preset("w4a4_mse"), preset("w4a4_abfp")),
            ("w4a8", preset("w4a8_mse"), preset("w4a8_abfp")),
        ):
            rptq_ppl = C.eval_ppl(model, params, pol_rptq, q=q_rptq)
            abfp_ppl = C.eval_ppl(model, params, pol_abfp)
            rows[fmt_name] = (rptq_ppl, abfp_ppl)
        rep.row("table8", model=name,
                rptq_w4a4=round(rows["w4a4"][0], 3),
                abfp_w4a4=round(rows["w4a4"][1], 3),
                rptq_w4a8=round(rows["w4a8"][0], 3),
                abfp_w4a8=round(rows["w4a8"][1], 3))
        rep.claim("table8",
                  f"{name}: ABFP beats RPTQ at W4A4",
                  rows["w4a4"][1] < rows["w4a4"][0],
                  f"abfp={rows['w4a4'][1]:.2f} rptq={rows['w4a4'][0]:.2f}")


# ------------------------------------------------------------- Figure 3
def fig3(rep: C.Report, steps: int):
    """E1M2 W+A for n=64 vs n=128: larger n hurts, gap shrinks with size."""
    gaps = {}
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        p64 = C.eval_ppl(model, params, preset("w4a4_e1m2", n=64))
        p128 = C.eval_ppl(model, params, preset("w4a4_e1m2", n=128))
        gaps[name] = (p128 - p64) / p64
        rep.row("fig3", model=name, n64=round(p64, 3), n128=round(p128, 3),
                rel_gap=round(gaps[name], 4))
        rep.claim("fig3", f"{name}: n=64 no worse than n=128",
                  p64 <= p128 * 1.02, f"n64={p64:.2f} n128={p128:.2f}")


# ----------------------------------------------------------- Figures 4/5
def fig45(rep: C.Report, steps: int, qat_steps: int):
    """QAT at n=128 approaches n=64 (W4A4 = Fig 4, W4A8 = Fig 5)."""
    for fmt, fig in (("w4a4_abfp", "fig4"), ("w4a8_abfp", "fig5")):
        for name in MODELS:
            cfg, model, params, _ = C.train_proxy(name, steps)
            out = {}
            for n in (64, 128):
                pol = preset(fmt, n=n)
                qp = C.finetune_qat(model, params, pol, steps=qat_steps)
                out[n] = {
                    "raw": C.eval_ppl(model, params, pol),
                    "qat": C.eval_ppl(model, qp, pol),
                }
            rep.row(fig, model=name,
                    abfp_n64=round(out[64]["raw"], 3),
                    qat_n64=round(out[64]["qat"], 3),
                    abfp_n128=round(out[128]["raw"], 3),
                    qat_n128=round(out[128]["qat"], 3))
            rep.claim(fig,
                      f"{name}: QAT improves both n; n=128-QAT near n=64-QAT",
                      out[64]["qat"] <= out[64]["raw"] * 1.01
                      and out[128]["qat"] <= out[128]["raw"] * 1.01
                      and out[128]["qat"] <= out[64]["qat"] * 1.15,
                      str({k: {kk: round(vv, 2) for kk, vv in v.items()}
                           for k, v in out.items()}))


# ---------------------------------------------------------------- Table X
TABLE10_ARCHS = ["qwen2-7b", "gemma2-9b", "mamba2-130m", "zamba2-7b",
                 "phi3.5-moe-42b-a6.6b", "internvl2-2b"]


def table10(rep: C.Report, steps: int):
    """ABFP W4A4/W4A8 across model families (reduced assigned archs)."""
    for name in MODELS + TABLE10_ARCHS:
        # reduced non-OPT archs run eager-unrolled (slower): half budget
        steps_a = steps if name in MODELS else max(steps // 2, 50)
        cfg, model, params, _ = C.train_proxy(name, steps_a)
        fp = C.eval_ppl(model, params, preset("fp32"))
        w4a4 = C.eval_ppl(model, params, preset("w4a4_abfp"))
        w4a8 = C.eval_ppl(model, params, preset("w4a8_abfp"))
        rep.row("table10", model=name, fp32=round(fp, 3),
                abfp_w4a4=round(w4a4, 3), abfp_w4a8=round(w4a8, 3))
        rep.claim("table10",
                  f"{name}: W4A8-ABFP close to FP32 out of the box",
                  w4a8 < 1.35 * fp and w4a8 <= w4a4 * 1.02,
                  f"fp={fp:.2f} w4a8={w4a8:.2f} w4a4={w4a4:.2f}")


# --------------------------------------------------------------- ViT table
VIT_MODELS = ["vit-proxy-s", "deit-proxy-s"]


def vit_table(rep: C.Report, steps: int):
    """Paper §III vision rows (ViT/DeiT): top-1 under W4A4 policies.

    Claims (qualitative, as in the paper's Tables II/III vision rows):
      * ABFP W4A4 stays near the fp32 baseline while static-MSE calibration
        degrades — the outlier-driven gap that motivates per-vector scaling.
      * E1M2 tracks INT4 under ABFP (near-uniform grid), with E2M1 reported
        alongside for the format-ordering comparison.
    """
    for name in VIT_MODELS:
        cfg, model, params, _ = C.train_vit_proxy(name, steps)
        fp = C.eval_top1(model, params, preset("fp32"))
        abfp = C.eval_top1(model, params, preset("w4a4_abfp"))
        w4a8 = C.eval_top1(model, params, preset("w4a8_abfp"))
        calib = C.calibrated_vit(name, model, params)
        q = C.run_recipe(name, model, params, "static_mse",
                         preset("w4a4_mse"), calib=calib,
                         batches=C.vit_calib_batches(model)).qtree
        mse = C.eval_top1(model, params, preset("w4a4_mse"), q=q)
        e2m1 = C.eval_top1(model, params, preset("w4a4_e2m1"))
        e1m2 = C.eval_top1(model, params, preset("w4a4_e1m2"))
        rep.row("vit_table", model=name, fp32=round(fp, 4),
                abfp_w4a4=round(abfp, 4), abfp_w4a8=round(w4a8, 4),
                mse_w4a4=round(mse, 4), e2m1=round(e2m1, 4),
                e1m2=round(e1m2, 4))
        rep.claim("vit_table",
                  f"{name}: W4A4-ABFP near fp32; static-MSE degrades",
                  abfp >= fp - 0.10 and mse < abfp - 0.02,
                  f"fp={fp:.3f} abfp={abfp:.3f} mse={mse:.3f}")
        rep.claim("vit_table",
                  f"{name}: E1M2 ~ INT4 under ABFP (near-uniform grid)",
                  abs(e1m2 - abfp) <= 0.10,
                  f"int4={abfp:.3f} e1m2={e1m2:.3f} e2m1={e2m1:.3f}")


# --------------------------------------------- mixed precision (PolicyMap)
def mixed_table(rep: C.Report, steps: int):
    """Layer-sensitivity sweep over site-addressed PolicyMaps.

    The paper's headline is *mixed* precision and formats; this table shows
    where the accuracy/efficiency frontier lives once assignments can vary
    per site:
      * uniform W4A4 static-MSE (the paper's fragile baseline), vs.
      * W8A8 endcap blocks + W4A4 interior (static-MSE, per-site alpha
        solving) — recovers accuracy at a fraction of uniform-W8A8's
        weight-bits budget, and
      * FP8-E4M3 attention + INT4-ABFP FFN (format mixing, not just width).
    Also asserts the cost-model side: the per-site bit-width report must
    agree exactly with the resolved map (what dryrun/roofline record).
    """
    from repro.core.policy import PolicyMap, PolicyRule
    from repro.launch import roofline as rf

    name = "opt-proxy-d"
    cfg, model, params, _ = C.train_proxy(name, steps)
    L = cfg.n_layers
    fp = C.eval_ppl(model, params, preset("fp32"))
    calib = C.calibrated(name, model, params)

    # --- uniform static-MSE baselines ----------------------------------
    q4 = C.run_recipe(name, model, params, "static_mse",
                      preset("w4a4_mse"), calib=calib).qtree
    u4_mse = C.eval_ppl(model, params, preset("w4a4_mse"), q=q4)
    q8 = C.run_recipe(name, model, params, "static_mse",
                      preset("w8a8_mse"), calib=calib).qtree
    u8_mse = C.eval_ppl(model, params, preset("w8a8_mse"), q=q8)

    # --- W8A8 endcaps / W4A4 interior (static-MSE, per-site solving) ----
    ends_mse = PolicyMap(
        name="w4a4_mse+w8a8_ends",
        rules=(
            PolicyRule("blocks.0/*", preset("w8a8_mse")),
            PolicyRule(f"blocks.{L - 1}/*", preset("w8a8_mse")),
        ),
        default=preset("w4a4_mse"),
    )
    # each site grid-searches alpha against ITS resolved format
    q_mixed = C.run_recipe(name, model, params, "static_mse", ends_mse,
                           calib=calib).qtree
    mixed_mse = C.eval_ppl(model, params, ends_mse, q=q_mixed)

    # --- ABFP variants (dynamic scaling; format mixing) -----------------
    u4_abfp = C.eval_ppl(model, params, preset("w4a4_abfp"))
    mixed_abfp = C.eval_ppl(
        model, params, preset("w4a4_abfp+w8a8_ends", n_layers=L))
    fp8attn = C.eval_ppl(model, params, preset("w4ffn_fp8attn"))

    # --- weight-bits budget (the roofline/dryrun cost-model view) -------
    bits = {
        pol_name: rf.policy_bits_report(cfg, pol)
        for pol_name, pol in (
            ("w8a8", preset("w8a8_mse")),
            ("w4a4", preset("w4a4_mse")),
            ("mixed_ends", ends_mse),
        )
    }
    ratio = (bits["mixed_ends"]["total_weight_bits"]
             / bits["w8a8"]["total_weight_bits"])

    rep.row("mixed_table", model=name, fp32=round(fp, 3),
            w4a4_mse=round(u4_mse, 3), w8a8_mse=round(u8_mse, 3),
            mixed_ends_mse=round(mixed_mse, 3),
            w4a4_abfp=round(u4_abfp, 3),
            mixed_ends_abfp=round(mixed_abfp, 3),
            fp8attn_int4ffn=round(fp8attn, 3),
            mixed_wbits_ratio=round(ratio, 4),
            mean_wbits=round(bits["mixed_ends"]["mean_weight_bits"], 3))

    rep.claim("mixed_table",
              f"{name}: W8A8-endcaps/W4A4-interior beats uniform W4A4 "
              "static-MSE at < 0.6x uniform-W8A8 weight-bits",
              mixed_mse < u4_mse and ratio < 0.6,
              f"mixed={mixed_mse:.2f} u4={u4_mse:.2f} ratio={ratio:.3f}")
    rep.claim("mixed_table",
              f"{name}: mixed static-MSE sits between its uniform endpoints",
              u8_mse * 0.98 <= mixed_mse <= u4_mse,
              f"u8={u8_mse:.2f} mixed={mixed_mse:.2f} u4={u4_mse:.2f}")
    rep.claim("mixed_table",
              f"{name}: mixed ABFP assignments stay near uniform W4A4 ABFP "
              "(ABFP already near-baseline at proxy scale)",
              mixed_abfp <= u4_abfp * 1.05 and fp8attn <= u4_abfp * 1.10,
              f"u4={u4_abfp:.2f} ends={mixed_abfp:.2f} fp8attn={fp8attn:.2f}")

    # cost-model consistency: per-site bits must equal the resolved map
    site_ok = all(
        (s["w_bits"] == 8) == (s["site"].startswith(("blocks.0/",
                                                     f"blocks.{L - 1}/")))
        for s in bits["mixed_ends"]["sites"]
    )
    rep.claim("mixed_table",
              f"{name}: per-site bit-width report consistent with the "
              "resolved PolicyMap (8b endcaps, 4b elsewhere)",
              site_ok,
              f"{len(bits['mixed_ends']['sites'])} sites checked")


# ------------------------------------------- method combinations (recipes)
def methods_table(rep: C.Report, steps: int):
    """The method-combination survey the QuantRecipe engine exists for.

    ZeroQuant-FP (arXiv:2307.09782) and "Integer or Floating Point?"
    (arXiv:2305.12356) both find the best W4A8 results come from *composing*
    difficulty migration (SmoothQuant) with second-order weight rounding
    (GPTQ).  This table runs single methods vs the ``smoothquant+gptq``
    composite at W4A8 static-MSE on the OPT proxy, every variant driven by
    a declarative recipe (the engine re-calibrates between param-mutating
    and stats-consuming passes automatically).

    Eval convention: GPTQ variants carry offline-quantized INT4 weights, so
    they run with the runtime weight quantizer off (the same W4A16-style
    convention table5 / ptq_pipeline use); SQ/static variants quantize
    weights at runtime (channel-max INT4).  Either way each variant is
    INT4 weights + INT8 static-MSE activations = W4A8.

    Claims:
      * the composite beats each constituent method alone, and
      * the recipe engine output is bit-exact with the correctly sequenced
        legacy manual driver chain it replaces.
    """
    name = "opt-proxy-m"
    # the proxy needs real structure for W4A8 orderings to clear noise:
    # at --quick's 60 steps every variant sits within +-0.01 PPL of fp32
    # (see EXPERIMENTS.md §Method-combination sweep); cached like all
    # benchmark models, so the floor costs one training run
    steps = max(steps, 400)
    cfg, model, params, _ = C.train_proxy(name, steps)
    pol = preset("w4a8_mse")
    pol_prequant = pol.replace(name="w4a8_mse_prequant", weight=None)
    fp = C.eval_ppl(model, params, preset("fp32"))
    calib = C.calibrated(name, model, params, outer=True)

    variants = {
        "static": ("static_mse", pol),
        "sq": ("smoothquant+static_mse", pol),
        "gptq": ("gptq+static_mse", pol_prequant),
        "sq_gptq": ("smoothquant+gptq+static_mse", pol_prequant),
    }
    ppl, results = {}, {}
    for label, (rname, eval_pol) in variants.items():
        res = C.run_recipe(name, model, params, rname, pol, calib=calib)
        results[label] = res
        # 24 eval batches: the composite-vs-gptq margin is real but small
        # (~0.1% PPL); the longer eval stream firms it up
        ppl[label] = C.eval_ppl(model, res.params, eval_pol, q=res.qtree,
                                max_batches=24)

    rep.row("methods_table", model=name, fp32=round(fp, 3),
            **{k: round(v, 3) for k, v in ppl.items()},
            composite_recalibrations=results["sq_gptq"].n_calibrations)
    rep.claim("methods_table",
              f"{name}: smoothquant+gptq composite beats each constituent "
              "alone at W4A8 static-MSE",
              ppl["sq_gptq"] < ppl["sq"] and ppl["sq_gptq"] < ppl["gptq"],
              f"sq+gptq={ppl['sq_gptq']:.3f} sq={ppl['sq']:.3f} "
              f"gptq={ppl['gptq']:.3f} static={ppl['static']:.3f} "
              f"fp={fp:.3f}")

    # --- bit-exactness vs the legacy manual driver chain ----------------
    # (calibrate -> SQ -> recalibrate w/ Hessians -> GPTQ -> recalibrate ->
    # static solve: what a careful caller had to hand-chain before)
    batches = C.calib_batches(model)
    obs = preset("w4a8_mse")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        p1 = qt.apply_smoothquant(params, calib)
        c2 = qt.calibrate(model, p1, batches, obs, collect_outer=True)
        p2, _ = qt.apply_gptq(p1, c2, INT4)
        c3 = qt.calibrate(model, p2, batches, obs)
        q_manual = qt.static_qtree(c3, pol, cfg.n_layers)
    res = results["sq_gptq"]
    same = _trees_equal(res.params, p2) and _trees_equal(res.qtree, q_manual)
    rep.claim("methods_table",
              f"{name}: recipe engine bit-exact with the legacy manual "
              "driver chain",
              same,
              f"{res.n_calibrations} auto-recalibrations")


# ------------------------------------------- compressed-domain serving
def serving_table(rep: C.Report, steps: int):
    """Compressed mixed-precision serving vs the QDQ-sim engine.

    "Give Me BF16 or Give Me Death" (arXiv:2411.02355) and ZeroQuant-FP
    (arXiv:2307.09782) both tie deployment value to weights *staying* in
    their compressed form; this table serves the OPT proxy through the
    ServeEngine twice per policy — QDQ simulation (dense weights, runtime
    weight QDQ) vs compressed-domain execution (per-site codes + scales,
    qmatmul's ``compressed`` backend) — and claims:

      * token output is IDENTICAL (greedy decode, same prompts), and
      * resident kernel bytes drop per the policy's bit budget: INT4 rules
        pack two codes per byte (+ f32 group scales), FP8 rules stay dense
        (prequantized), so the flat INT4-weight policy lands near 4.5/32
        of dense-f32 bytes and the FP8-attn/INT4-FFN map near the
        params-weighted blend.

    Throughput (tok/s) is recorded for both engines; on CPU the compressed
    path pays unpack/einsum overhead — the claim is about bytes + parity,
    the TPU win is the dryrun's ``weight_bytes``/roofline record.

    Paged-KV rows (PagedServeEngine): token identity vs the fixed-slot
    engine on the same trace, tokens/sec at two offered-load points (queue
    at slot capacity vs 4x oversubscribed — the paged pool admits by page
    availability, so throughput holds while the fixed engine's utilization
    story degrades), and resident-KV-byte accounting for INT8 pages
    (per-(page, head) scales reported separately; the <= 0.5x claim is on
    code bytes vs the fp16-equivalent occupancy).
    """
    import time

    from repro.core.policy import with_kv_cache
    from repro.serve.engine import PagedServeEngine, Request, ServeEngine

    name = "opt-proxy-s"
    cfg, model, params, _ = C.train_proxy(name, steps)
    rng = np.random.RandomState(11)
    prompts = [
        rng.randint(0, cfg.vocab, int(rng.randint(4, 12))).astype(np.int32)
        for _ in range(6)
    ]

    def run_engine(policy, compress):
        eng = ServeEngine(model, params, n_slots=3, max_len=96,
                          policy=policy, compress=compress)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        t0 = time.perf_counter()
        toks = {c.uid: c.tokens for c in eng.run_until_done()}
        dt = time.perf_counter() - t0
        total = sum(len(t) for t in toks.values())
        return eng, toks, total / dt

    from repro.models.serving_transforms import weight_bytes_summary

    fixed_toks_w4 = pol_w4 = None
    for pol_name, ratio_bound in (("w4a8_abfp", 0.20),
                                  ("w4ffn_fp8attn", 0.50)):
        pol = preset(pol_name, n_layers=cfg.n_layers)
        _, sim_toks, sim_tps = run_engine(pol, compress=False)
        if pol_name == "w4a8_abfp":
            fixed_toks_w4, pol_w4 = sim_toks, pol
        eng_c, comp_toks, comp_tps = run_engine(pol, compress=True)
        wb = eng_c.weight_bytes
        match = comp_toks == sim_toks
        rep.row("serving_table", model=name, policy=pol_name,
                tokens_match=match,
                **weight_bytes_summary(wb),
                sim_tok_s=round(sim_tps, 1),
                compressed_tok_s=round(comp_tps, 1))
        rep.claim("serving_table",
                  f"{name}/{pol_name}: compressed serving emits the "
                  "QDQ-sim engine's tokens",
                  match,
                  f"{sum(len(t) for t in sim_toks.values())} tokens, "
                  f"{len(prompts)} requests")
        rep.claim("serving_table",
                  f"{name}/{pol_name}: resident weight bytes cut per the "
                  f"policy bit budget (ratio < {ratio_bound})",
                  wb["compressed_sites"] > 0 and wb["ratio"] < ratio_bound,
                  f"ratio={wb['ratio']:.4f} "
                  f"({wb['compressed_sites']} compressed / "
                  f"{wb['dense_sites']} dense sites)")

    # --- paged-KV engine: identity, offered-load sweep, KV residency -----
    def run_paged(policy, reqs, kv="auto"):
        eng = PagedServeEngine(model, params, n_slots=3, max_len=96,
                               policy=policy, page_size=8,
                               prefill_chunk=16, kv=kv)
        for i, p in reqs:
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        t0 = time.perf_counter()
        toks = {c.uid: c.tokens for c in eng.run_until_done()}
        dt = time.perf_counter() - t0
        return eng, toks, sum(len(t) for t in toks.values()) / dt

    eng_p, paged_toks, _ = run_paged(pol_w4, list(enumerate(prompts)))
    ident = paged_toks == fixed_toks_w4
    leak = eng_p.page_stats()["pages_in_use"]
    rep.claim("serving_table",
              f"{name}/w4a8_abfp: paged-KV engine emits the fixed-slot "
              "engine's tokens and frees every page",
              ident and leak == 0,
              f"{sum(len(t) for t in paged_toks.values())} tokens, "
              f"{leak} pages leaked")

    # offered load: queue depth at admission, in requests (3 slots)
    load_prompts = [
        rng.randint(0, cfg.vocab, int(rng.randint(4, 12))).astype(np.int32)
        for _ in range(12)
    ]
    for load in (3, 12):
        eng_l, _, tps = run_paged(pol_w4, list(enumerate(
            load_prompts[:load])))
        st = eng_l.page_stats()
        rep.row("serving_table", model=name, policy="w4a8_abfp",
                paged=True, offered_load=load, tok_s=round(tps, 1),
                pages_peak=st["pages_peak"],
                pages_leaked=st["pages_in_use"])

    # INT8 pages: capture occupancy MID-FLIGHT (the drained pool holds 0)
    eng8 = PagedServeEngine(model, params, n_slots=3, max_len=96,
                            policy=with_kv_cache(pol_w4, "int8"),
                            page_size=8, prefill_chunk=16)
    for i, p in enumerate(prompts):
        eng8.submit(Request(uid=i, prompt=p, max_new_tokens=8))
    for _ in range(3):
        eng8.tick()
    kvb = eng8.kv_bytes()
    eng8.run_until_done()
    rep.row("serving_table", model=name, policy="w4a8_abfp", paged=True,
            kv="int8",
            kv_resident_bytes=kvb["kv_resident_bytes"],
            kv_code_bytes=kvb["kv_code_bytes"],
            kv_scale_bytes=kvb["kv_scale_bytes"],
            kv_fp16_equiv_bytes=kvb["kv_fp16_equiv_bytes"],
            kv_vs_fp16_ratio=kvb["kv_vs_fp16_ratio"])
    rep.claim("serving_table",
              f"{name}: INT8 KV pages hold <= 0.5x the fp16-equivalent "
              "resident bytes (codes; scales are metadata)",
              kvb["kv_code_bytes"] > 0
              and kvb["kv_code_bytes"] <= 0.5 * kvb["kv_fp16_equiv_bytes"],
              f"codes={kvb['kv_code_bytes']} "
              f"scales={kvb['kv_scale_bytes']} "
              f"fp16_equiv={kvb['kv_fp16_equiv_bytes']}")


# --------------------------------------- compressed-domain attention
def attn_table(rep: C.Report, steps: int):
    """Compressed-domain flash attention: decode throughput + attention
    HBM read bytes per backend x page format.

    The serving engines' decode attention can contract the paged KV three
    ways: ``ref`` (gather -> dequantize -> jnp reference — the QDQ-sim
    baseline), ``fused`` (dense Pallas kernel; decode steps stay on the
    reference path, the row is the control), and ``compressed`` (the
    quantized flash kernel consumes stored int8/fp8 codes + per-(page,
    head) scales directly — the dense K/V is never materialized in HBM).
    Rows record tok/s and the attention read accounting
    (``kv_pages.attention_read_bytes``) captured mid-flight; claims:

      * compressed serving is TOKEN-IDENTICAL to the ref backend on the
        same trace and the same page storage (int8 and fp8), and
      * at token identity the compressed read path moves <= 0.5x the
        dense-fp16-equivalent bytes (codes vs 2-byte entries; page scales
        amortize to metadata) — the QDQ-sim path reads the codes AND a
        dense round-trip, so compressed is also strictly below it.

    tok/s on CPU runs the kernel under the Pallas interpreter — the
    wall-clock column is context, not the claim (EXPERIMENTS.md
    §Compressed attention).
    """
    import time

    from repro.core.policy import with_attn_backend, with_kv_cache
    from repro.serve.engine import PagedServeEngine, Request

    name = "opt-proxy-s"
    cfg, model, params, _ = C.train_proxy(name, steps)
    rng = np.random.RandomState(31)
    prompts = [
        rng.randint(0, cfg.vocab, int(rng.randint(4, 12))).astype(np.int32)
        for _ in range(6)
    ]

    def run(policy, kv, backend):
        pol = policy if backend == "auto" else with_attn_backend(policy,
                                                                 backend)
        eng = PagedServeEngine(model, params, n_slots=3, max_len=96,
                               policy=pol, page_size=8, prefill_chunk=16,
                               kv=kv)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        # occupancy-dependent read accounting: capture MID-FLIGHT (the
        # drained pool reads 0 bytes)
        for _ in range(3):
            eng.tick()
        kvb = eng.kv_bytes()
        t0 = time.perf_counter()
        toks = {c.uid: c.tokens for c in eng.run_until_done()}
        dt = time.perf_counter() - t0
        tps = sum(len(t) for t in toks.values()) / dt
        return toks, tps, kvb

    pol = preset("w4a8_abfp", n_layers=cfg.n_layers)
    for kv in ("fp", "int8", "fp8"):
        base = with_kv_cache(pol, kv) if kv != "fp" else pol
        ref_run = run(base, kv, "ref")
        ref_toks = ref_run[0]
        backends = ["ref", "fused"] + (["compressed"] if kv != "fp" else [])
        for backend in backends:
            toks, tps, kvb = ref_run if backend == "ref" \
                else run(base, kv, backend)
            match = toks == ref_toks
            rep.row("attn_table", model=name, policy="w4a8_abfp", kv=kv,
                    backend=backend, tokens_match=match,
                    tok_s=round(tps, 1),
                    attn_kv_read_bytes=kvb["attn_kv_read_bytes"],
                    attn_code_read_bytes=kvb["attn_code_read_bytes"],
                    attn_scale_read_bytes=kvb["attn_scale_read_bytes"],
                    attn_fp16_equiv_read_bytes=kvb[
                        "attn_fp16_equiv_read_bytes"],
                    attn_vs_fp16_read_ratio=kvb.get(
                        "attn_vs_fp16_read_ratio"))
            if backend == "compressed":
                rep.claim("attn_table",
                          f"{name}/{kv}: compressed attention emits the "
                          "ref backend's tokens",
                          match,
                          f"{sum(len(t) for t in toks.values())} tokens, "
                          f"{len(prompts)} requests")
                ok = (match and kvb["attn_code_read_bytes"] > 0
                      and kvb["attn_code_read_bytes"]
                      <= 0.5 * kvb["attn_fp16_equiv_read_bytes"])
                rep.claim("attn_table",
                          f"{name}/{kv}: at token identity the compressed "
                          "read path moves <= 0.5x the dense-fp16-"
                          "equivalent bytes",
                          ok,
                          f"codes={kvb['attn_code_read_bytes']} "
                          f"scales={kvb['attn_scale_read_bytes']} "
                          f"fp16_equiv="
                          f"{kvb['attn_fp16_equiv_read_bytes']}")


def spec_table(rep: C.Report, steps: int):
    """Self-speculative serving: a compressed low-precision draft of the
    SAME weights proposes draft_k tokens per round; the fp32 target scores
    them in one chunked verify pass and keeps the longest agreeing prefix.

    Sweep over draft precisions (W4A4-ABFP, W4A8-ABFP, native-INT8 W8A8,
    FP8-attn/INT4-FFN mixed) against one fp32 target on a mixed-length
    trace through the paged engine, claiming:

      * greedy speculative output is TOKEN-IDENTICAL to target-only
        greedy serving (exact-match acceptance makes this structural, so
        any divergence is an engine bug, not a quality tradeoff),
      * both page pools drain clean (allocs == frees, zero in use) —
        rollback is a position reset, pages never move, and
      * the W4A8-ABFP draft emits > 1.0 accepted tokens per target
        verify pass — the draft pays for itself in target steps (the
        wall-clock win needs the TPU byte ratio; on CPU the row records
        tok/s for both engines as context, not as the claim).

    Acceptance rates are recorded per draft but NOT claimed to order by
    draft width — on tiny proxies the draft/target agreement is noisy
    (methodology in EXPERIMENTS.md §Speculative acceptance).
    """
    import time

    from repro.models.serving_transforms import weight_bytes_summary
    from repro.serve.engine import PagedServeEngine, Request
    from repro.serve.speculative import SpeculativeServeEngine

    name = "opt-proxy-s"
    cfg, model, params, _ = C.train_proxy(name, steps)
    rng = np.random.RandomState(23)
    prompts = [rng.randint(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 11, 3, 17, 8, 2)]

    def drive(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        t0 = time.perf_counter()
        toks = {c.uid: c.tokens for c in eng.run_until_done()}
        dt = time.perf_counter() - t0
        return toks, sum(len(t) for t in toks.values()) / dt

    target = preset("fp32")
    base_eng = PagedServeEngine(model, params, n_slots=3, max_len=96,
                                policy=target, page_size=8,
                                prefill_chunk=16)
    base_toks, base_tps = drive(base_eng)

    per_step = {}
    for dname in ("w4a4_abfp", "w4a8_abfp", "w8a8_int8_native",
                  "w4ffn_fp8attn"):
        eng = SpeculativeServeEngine(
            model, params, target_policy=target,
            draft_policy=preset(dname, n_layers=cfg.n_layers),
            draft_k=3, n_slots=3, max_len=96, kv_cache="paged",
            page_size=8, prefill_chunk=16)
        toks, tps = drive(eng)
        st = eng.acceptance_stats()
        pg = eng.page_stats()
        leaked = (pg["draft"]["pages_in_use"]
                  + pg["target"]["pages_in_use"])
        frees = min(pg[s]["page_frees"] for s in ("draft", "target"))
        match = toks == base_toks
        per_step[dname] = st["accepted_per_target_step"]
        wb = weight_bytes_summary(eng.weight_bytes)
        rep.row("spec_table", model=name, draft=dname,
                draft_k=st["draft_k"], tokens_match=match,
                acceptance_rate=round(st["acceptance_rate"], 4),
                accepted_per_target_step=round(
                    st["accepted_per_target_step"], 4),
                target_steps=st["target_steps"],
                pages_leaked=leaked,
                draft_weight_ratio=wb["weight_bytes_ratio"],
                spec_tok_s=round(tps, 1),
                target_only_tok_s=round(base_tps, 1))
        rep.claim("spec_table",
                  f"{name}/{dname}: greedy speculative serving emits the "
                  "target-only engine's tokens and both pools drain clean",
                  match and leaked == 0 and frees > 0,
                  f"{sum(len(t) for t in toks.values())} tokens, "
                  f"{leaked} pages leaked, "
                  f"accepted/step={st['accepted_per_target_step']:.3f}")
    rep.claim("spec_table",
              f"{name}: the W4A8-ABFP draft emits > 1.0 accepted tokens "
              "per target verify pass",
              per_step["w4a8_abfp"] > 1.0,
              f"accepted_per_target_step={per_step['w4a8_abfp']:.3f} "
              f"(ceiling draft_k+1=4)")


# ------------------------------------------------- beyond-paper ablations
def output_quant(rep: C.Report, steps: int):
    """Paper §III supports output quantizers (f_q^y, eqn (9)) 'for alternate
    hardware configurations' (photonics ADCs) but never evaluates them.
    Ablation: W4A8-ABFP with int8/e4m3/int4 OUTPUT quantization."""
    from repro.core.policy import TensorQuant

    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        base = preset("w4a8_abfp")
        res = {"none": C.eval_ppl(model, params, base)}
        for fmt in ("int8", "e4m3", "int4"):
            pol = base.replace(
                name=f"w4a8_y{fmt}",
                output=TensorQuant(fmt_name=fmt, scaler="abfp", group=64),
            )
            res[fmt] = C.eval_ppl(model, params, pol)
        rep.row("output_quant", model=name,
                **{f"y_{k}": round(v, 3) for k, v in res.items()})
        rep.claim("output_quant",
                  f"{name}: 8-bit output quant is ~free; 4-bit degrades",
                  res["int8"] < 1.05 * res["none"]
                  and res["e4m3"] < 1.05 * res["none"]
                  and res["int4"] > res["int8"],
                  str({k: round(v, 2) for k, v in res.items()}))


def int8_native(rep: C.Report, steps: int):
    """Beyond-paper: native int8 MXU compute (codes contracted in int32)
    must match the paper's QDQ-then-fp-matmul simulation numerically."""
    for name in MODELS:
        cfg, model, params, _ = C.train_proxy(name, steps)
        sim = C.eval_ppl(model, params, preset("w8a8_int8_native")
                         .replace(compute="fp", attn_bmm=False))
        native = C.eval_ppl(model, params, preset("w8a8_int8_native"))
        rep.row("int8_native", model=name, simulated=round(sim, 4),
                native=round(native, 4))
        rep.claim("int8_native",
                  f"{name}: native int8 path == fp-simulated path",
                  abs(native - sim) / sim < 0.002,
                  f"sim={sim:.3f} native={native:.3f}")


def moe_table(rep: C.Report, steps: int):
    """Expert-resident MoE serving (serve.experts): compressed per-expert
    store + LRU cache on the phi3.5-moe reduced proxy, plus a synthetic
    uniform-vs-Zipf routing-skew sweep of the LRU itself.

    Claims:

      * expert-store serving (W4A8-ABFP compressed banks, cache capacity
        E//4) is TOKEN-IDENTICAL to dense-resident serving — cache state
        is pure representation, so hits/misses can never change tokens,
      * the resident expert bytes (INT4/INT8 backing store + dense cached
        copies) stay <= 0.5x the dense-f32 expert footprint at E//4, and
      * on a synthetic routing trace, Zipf-skewed traffic hits the LRU
        strictly more often than uniform traffic at the same capacity,
        with the hit rate monotone in capacity (LRU inclusion property —
        methodology in EXPERIMENTS.md §Expert residency).
    """
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.experts import ExpertCache, zipf_trace

    name = "phi3.5-moe-42b-a6.6b"
    # reduced non-OPT archs run eager-unrolled (slower): half budget
    cfg, model, params, _ = C.train_proxy(name, max(steps // 2, 50))
    pol = preset("w4a8_abfp")
    rng = np.random.RandomState(31)
    prompts = [rng.randint(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 11, 3, 8)]

    def drive(**kw):
        eng = ServeEngine(model, params, n_slots=2, max_len=96,
                          policy=pol, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        return {c.uid: c.tokens for c in eng.run_until_done()}, eng

    dense_toks, _ = drive()
    cap = max(1, cfg.n_experts // 4)
    store_toks, eng = drive(compress=True, expert_cache=cap)
    st = eng.expert_stats()
    match = store_toks == dense_toks
    rep.row("moe_table", model=name, policy="w4a8_abfp",
            n_experts=st["n_experts"], capacity=cap,
            tokens_match=match,
            hits=st["hits"], misses=st["misses"],
            evictions=st["evictions"],
            hit_rate=round(st["hit_rate"], 4),
            store_bytes=st["store_bytes"],
            cache_bytes=st["cache_bytes"],
            resident_ratio=round(st["ratio"], 4))
    rep.claim("moe_table",
              f"{name}: expert-store serving (cache E//4) is "
              "token-identical to dense-resident serving",
              match and st["misses"] > 0,
              f"{sum(len(t) for t in store_toks.values())} tokens, "
              f"hits={st['hits']} misses={st['misses']}")
    rep.claim("moe_table",
              f"{name}: resident expert bytes <= 0.5x dense-f32 at "
              "cache capacity E//4",
              0 < st["resident_bytes"] <= 0.5 * st["dense_bytes"],
              f"resident={st['resident_bytes']} "
              f"dense={st['dense_bytes']} ratio={st['ratio']:.3f}")

    # synthetic LRU sweep: routing-skew knob (alpha=0 uniform vs Zipf)
    E, T, top_k = 16, 400, 2

    def lru_hit_rate(alpha: float, capacity: int) -> float:
        cache = ExpertCache(capacity)
        for row in zipf_trace(E, T, alpha=alpha, top_k=top_k, seed=7):
            for e in np.nonzero(row)[0]:
                if not cache.access(int(e)):
                    cache.admit(int(e), None)
        return cache.hit_rate

    uni = lru_hit_rate(0.0, E // 4)
    zipf = lru_hit_rate(1.5, E // 4)
    by_cap = {c: lru_hit_rate(1.5, c) for c in (2, 4, 8, 16)}
    rep.row("moe_table", model="synthetic-lru", n_experts=E,
            capacity=E // 4, uniform_hit_rate=round(uni, 4),
            zipf_hit_rate=round(zipf, 4),
            **{f"zipf_cap{c}": round(r, 4) for c, r in by_cap.items()})
    rep.claim("moe_table",
              f"synthetic E={E} cap={E // 4}: Zipf-skewed routing hits "
              "the LRU more often than uniform routing",
              zipf > uni,
              f"zipf={zipf:.3f} uniform={uni:.3f}")
    caps = sorted(by_cap)
    rep.claim("moe_table",
              f"synthetic E={E}: LRU hit rate is monotone in capacity",
              all(by_cap[a] <= by_cap[b] + 1e-12
                  for a, b in zip(caps, caps[1:])),
              str({c: round(r, 3) for c, r in by_cap.items()}))


ALL = {
    "table1": table1, "table2": table2, "table3": table3, "table4": table4,
    "table5": table5, "table6": table6, "table7": table7, "table8": table8,
    "fig3": fig3, "fig45": fig45, "table10": table10,
    "vit_table": vit_table, "mixed_table": mixed_table,
    "methods_table": methods_table, "serving_table": serving_table,
    "spec_table": spec_table, "moe_table": moe_table,
    "attn_table": attn_table,
    "output_quant": output_quant, "int8_native": int8_native,
}
